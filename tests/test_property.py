"""Hypothesis property tests for the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import blocked, tuning
from repro.core.grid import (cyclic_perm, inv_perm, to_cyclic_matrix,
                             from_cyclic_matrix, to_cyclic_rows,
                             from_cyclic_rows)


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


pow2 = st.sampled_from([1, 2, 4, 8, 16, 32, 64])


@given(n=pow2, p=pow2)
@settings(max_examples=40, deadline=None)
def test_cyclic_perm_roundtrip(n, p):
    if p > n or n % p:
        return
    perm = cyclic_perm(n, p)
    assert np.array_equal(np.sort(perm), np.arange(n))
    assert np.array_equal(perm[inv_perm(perm)], np.arange(n))
    a = np.random.default_rng(0).standard_normal((n, 3))
    assert np.array_equal(from_cyclic_rows(to_cyclic_rows(a, p), p), a)


@given(n=st.sampled_from([8, 16, 32]), pr=st.sampled_from([1, 2, 4]),
       pc=st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=30, deadline=None)
def test_cyclic_matrix_roundtrip(n, pr, pc):
    a = np.random.default_rng(1).standard_normal((n, n))
    assert np.array_equal(
        from_cyclic_matrix(to_cyclic_matrix(a, pr, pc), pr, pc), a)


@given(n=st.sampled_from([1, 2, 3, 4, 7, 8, 16, 33, 64]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_tri_inv_doubling_identity(n, seed):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    Li = blocked.tri_inv_doubling(jnp.asarray(L))
    np.testing.assert_allclose(np.asarray(Li) @ L, np.eye(n), atol=1e-8)
    # inverse of lower-triangular stays lower-triangular
    assert np.allclose(np.triu(np.asarray(Li), 1), 0.0)


@given(n=st.sampled_from([8, 16, 32, 64]),
       kk=st.sampled_from([1, 2, 5, 16, 64]),
       n0=st.sampled_from([1, 2, 4, 8, 16]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=30, deadline=None)
def test_it_inv_trsm_solves(n, kk, n0, seed):
    if n % n0:
        return
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, kk))
    X = blocked.it_inv_trsm_local(jnp.asarray(L), jnp.asarray(B), n0)
    np.testing.assert_allclose(np.asarray(L @ X), B, atol=1e-8)


@given(n=st.sampled_from([8, 16, 32]), kk=st.sampled_from([1, 4, 8]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_inv_and_rec_agree(n, kk, seed):
    """The paper's two algorithm families must produce the same solve."""
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, kk))
    Xi = blocked.it_inv_trsm_local(jnp.asarray(L), jnp.asarray(B), 4)
    Xr = blocked.rec_trsm_local(jnp.asarray(L), jnp.asarray(B), 4)
    np.testing.assert_allclose(np.asarray(Xi), np.asarray(Xr), atol=1e-8)


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_upper_solve_reduction(seed):
    rng = np.random.default_rng(seed)
    n, kk = 16, 4
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, kk))
    solver = lambda l, b: blocked.it_inv_trsm_local(l, b, 4)
    XU = blocked.solve_upper(jnp.asarray(L.T), jnp.asarray(B), solver)
    np.testing.assert_allclose(L.T @ np.asarray(XU), B, atol=1e-8)


@given(n=st.sampled_from([8, 16, 32]), bs=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_cholesky_factorization(n, bs, seed):
    if bs > n:
        return
    from repro.core import cholesky
    rng = np.random.default_rng(seed)
    M = rng.standard_normal((n, n))
    A = M @ M.T + n * np.eye(n)
    L = cholesky.chol_blocked_local(jnp.asarray(A), bs)
    np.testing.assert_allclose(np.asarray(L @ L.T), A, atol=1e-7)


@given(n0=st.integers(1, 128), mult=st.integers(1, 32),
       p=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_inv_subgrid_is_feasible(n0, mult, p):
    """The Sec. VI-A inversion subgrid is a processor ASSIGNMENT:
    whatever (n, n0, p) the tuner visits, the snapped (r1, r2) must
    satisfy r1^2 * r2 <= p (power-of-two rounding used to oversubscribe
    — e.g. q = 6 snapped r2 from 3 up to 8), and both factors must stay
    positive powers of two."""
    n = n0 * mult                       # n0 always divides n
    r1, r2 = tuning._inv_subgrid(n, n0, p)
    assert r1 >= 1 and r2 >= 1
    assert r1 & (r1 - 1) == 0 and r2 & (r2 - 1) == 0
    assert r1 * r1 * r2 <= p, (n, n0, p, r1, r2)


@given(n=st.sampled_from([2 ** e for e in range(4, 13)]),
       k=st.integers(1, 1 << 12), p=st.integers(1, 1024),
       hoisted=st.booleans())
@settings(max_examples=120, deadline=None)
def test_auto_planned_specs_are_feasible(n, k, p, hoisted):
    """SolveSpec.auto must ALWAYS emit a feasible plan across random
    (n, k, p): the inversion subgrid fits the machine (r1^2 r2 <= p),
    n0 tiles the factor (n0 | n), and n0 respects the cyclic layout
    ((p1*p2) | n0 — every rank owns a contiguous slice of each
    diagonal block) — whether the plan comes from the fused-solve
    argmin or the hoisted-serving argmin."""
    from repro.core.solver import SolveSpec
    spec = SolveSpec.auto(n, k, p=p, hoisted=hoisted)
    plan = tuning.tune(n, k, p)
    assert plan.r1 ** 2 * plan.r2 <= p
    assert spec.n0 >= 1 and n % spec.n0 == 0
    g = spec.grid
    assert g.p1 ** 2 * g.p2 <= p
    if tuning.feasible_grids(p):
        # p factors exactly: the plan must use the whole machine
        assert g.p1 ** 2 * g.p2 == p
    if spec.method == "inv":
        assert spec.n0 % (g.p1 * g.p2) == 0
    spec.validate()                     # must not raise
    # and the spec is hashable + equal to its reconstruction (it is
    # the compiled-program cache key)
    assert hash(spec) == hash(SolveSpec.auto(n, k, p=p, hoisted=hoisted))


@given(n=st.sampled_from([2 ** e for e in range(4, 13)]),
       k=st.integers(1, 1 << 12), p=st.integers(1, 1024),
       hoisted=st.booleans(),
       structure=st.sampled_from(["banded8", "banded4", "block", None]),
       overlap=st.sampled_from(["auto", "on", "off"]))
@settings(max_examples=120, deadline=None)
def test_auto_planned_structured_specs_are_feasible(n, k, p, hoisted,
                                                    structure, overlap):
    """The same always-feasible property over the full spec surface:
    a non-dense structure (which swings BOTH sides of the rec/inv
    dispatch pricing) and any overlap spelling must still yield a
    valid, stable-keyed plan."""
    from repro.core.solver import SolveSpec
    from repro.core.structure import FactorStructure
    stx = {"banded8": FactorStructure.banded(max(n // 8, 1)),
           "banded4": FactorStructure.banded(max(n // 4, 1)),
           "block": FactorStructure.block_sparse(
               [[True, False], [True, True]]),
           None: None}[structure]
    spec = SolveSpec.auto(n, k, p=p, hoisted=hoisted, structure=stx,
                          overlap=overlap)
    assert spec.n0 >= 1 and n % spec.n0 == 0
    g = spec.grid
    assert g.p1 ** 2 * g.p2 <= p
    if spec.method == "inv":
        assert spec.n0 % (g.p1 * g.p2) == 0
    assert spec.overlap == ("on" if overlap in ("auto", "on") else None)
    spec.validate()
    assert hash(spec) == hash(SolveSpec.auto(n, k, p=p, hoisted=hoisted,
                                             structure=stx,
                                             overlap=overlap))
    # structure-aware pricing holds on both sides of the dispatch
    if stx is not None:
        from repro.core import cost_model as cm
        rd, rs = (cm.rec_trsm_cost(n, k, p),
                  cm.rec_trsm_cost(n, k, p, structure=stx))
        assert rs.s == rd.s and rs.w <= rd.w and rs.f <= rd.f


@given(n=pow2, p=pow2, reverse=st.booleans(), k=st.sampled_from([1, 3, 8]))
@settings(max_examples=40, deadline=None)
def test_device_cyclic_rows_matches_numpy(n, p, reverse, k):
    """On-device cyclic row permutation (with the upper/transpose
    reversal folded in) == NumPy reference, and it round-trips."""
    from repro.core.grid import cyclic_rows_device
    if p > n or n % p:
        return
    a = np.random.default_rng(n + p).standard_normal((n, k))
    fwd = np.asarray(cyclic_rows_device(jnp.asarray(a), p,
                                        reverse=reverse))
    ref = to_cyclic_rows(a[::-1] if reverse else a, p)
    np.testing.assert_array_equal(fwd, ref)
    back = np.asarray(cyclic_rows_device(jnp.asarray(fwd), p,
                                         inverse=True, reverse=reverse))
    np.testing.assert_array_equal(back, a)


@given(n=st.sampled_from([8, 16, 32]), pr=st.sampled_from([1, 2, 4]),
       pc=st.sampled_from([1, 2, 4, 8]), reverse=st.booleans(),
       transpose=st.booleans())
@settings(max_examples=40, deadline=None)
def test_device_cyclic_matrix_matches_numpy(n, pr, pc, reverse, transpose):
    """On-device matrix distribution (transpose/reversal composed into
    the gather) == the NumPy reference applied to the reduced operator —
    the identity behind device-resident lower/upper/transposed solves."""
    from repro.core.grid import cyclic_matrix_device
    A = np.random.default_rng(n * pr + pc).standard_normal((n, n))
    dev = np.asarray(cyclic_matrix_device(
        jnp.asarray(A), pr, pc, reverse_rows=reverse, reverse_cols=reverse,
        transpose=transpose))
    Aeff = A.T if transpose else A
    if reverse:
        Aeff = Aeff[::-1, ::-1]
    np.testing.assert_array_equal(dev, to_cyclic_matrix(Aeff, pr, pc))
    back = np.asarray(cyclic_matrix_device(jnp.asarray(dev), pr, pc,
                                           inverse=True))
    np.testing.assert_array_equal(back, Aeff)


@given(widths=st.lists(st.integers(1, 8), min_size=1, max_size=24),
       panel_k=st.integers(8, 12))
@settings(max_examples=60, deadline=None)
def test_pack_wave_fifo_width_bound_no_starvation(widths, panel_k):
    """SolveServer wave packing invariants: every wave respects the
    panel width bound, takes the queue head (so no request starves
    across repeated waves), and preserves FIFO order both for the
    packed wave and for the skipped leftovers."""
    import collections
    import numpy as np
    from repro.core.solver import _pack_wave

    class _Req:    # shape[1] is all _pack_wave reads; no arrays needed
        def __init__(self, w):
            self.shape = (1, w)

    queue = collections.deque((seq, _Req(w))
                              for seq, w in enumerate(widths))
    served, waves = [], 0
    while queue:
        before = [seq for seq, _ in queue]
        wave = _pack_wave(queue, panel_k)
        waves += 1
        assert wave, "a nonempty queue must always yield a wave"
        assert sum(b.shape[1] for _, b in wave) <= panel_k
        assert wave[0][0] == before[0], "head of line must be served"
        seqs = [seq for seq, _ in wave]
        assert seqs == sorted(seqs), "packed wave must keep FIFO order"
        leftover = [seq for seq, _ in queue]
        assert leftover == [s for s in before if s not in set(seqs)], \
            "skipped requests must keep their relative order"
        served.extend(seqs)
    assert sorted(served) == list(range(len(widths)))   # no starvation
    assert waves <= len(widths)
    # lower bound: a wave carries at most panel_k columns
    assert waves >= int(np.ceil(sum(widths) / panel_k))


@pytest.fixture(scope="module")
def _lifecycle_bank():
    """One capacity bank + solver shared by every hypothesis example
    (the compiled programs depend only on (n, C), so examples reuse
    them; each example rebuilds the occupancy it needs)."""
    from repro import api
    grid = api.make_trsm_mesh(1, 1)
    n, C = 16, 3
    bank = api.FactorBank(grid, n, n0=8, capacity=C, dtype=np.float32)
    solver = api.Solver.from_bank(bank).warmup(4)
    return bank, solver


@given(ops=st.lists(st.sampled_from(["admit", "evict", "replace"]),
                    max_size=10),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_bank_slot_lifecycle(_lifecycle_bank, ops, seed):
    """Slot lifecycle invariants under random admit/evict/replace
    churn: admit fills the LOWEST free slot (evict -> admit reuses
    it), live bookkeeping stays exact, and a batched solve returns
    each live slot's OWN solution (factors c*I solve to B/c, so every
    lane is attributable)."""
    from repro import api
    bank, solver = _lifecycle_bank
    n, C = bank.n, bank.capacity
    rng = np.random.default_rng(seed)
    for slot in bank.live_slots():         # reset occupancy
        bank.evict(slot)
    live = {}
    scale = 2.0
    for op in ops:
        if op == "admit" and bank.size < C:
            expect = min(set(range(C)) - set(live))
            slot = bank.admit(scale * np.eye(n, dtype=np.float32))
            assert slot == expect, "admit must fill the lowest free slot"
            live[slot] = scale
            scale += 1.0
        elif op == "evict" and live:
            slot = rng.choice(sorted(live))
            bank.evict(int(slot))
            del live[slot]
            assert not bank.is_live(int(slot))
        elif op == "replace" and live:
            slot = int(rng.choice(sorted(live)))
            bank.replace(slot, scale * np.eye(n, dtype=np.float32))
            live[slot] = scale
            scale += 1.0
        assert bank.live_slots() == tuple(sorted(live))
        assert bank.size == len(live) and bank.width == C
    B = rng.standard_normal((C, n, 4)).astype(np.float32)
    ref = B.copy()
    X = np.asarray(solver.solve(solver.place_rhs(B)))
    for slot, c in live.items():           # results keyed correctly:
        np.testing.assert_allclose(X[slot], ref[slot] / c, atol=1e-5)


@pytest.fixture(scope="module")
def _pad_banks():
    """Width-1 capacity banks shared by every hypothesis example,
    keyed by (order, lower, transpose) and built lazily — each
    example replaces the resident factor through the compiled updater
    instead of recompiling (n=16 is the bucket order, n0=4 divides
    every sampled d, so padded and unpadded runs share a blocking)."""
    from repro import api
    grid = api.make_trsm_mesh(1, 1)
    banks = {}

    def get(d, lower, transpose):
        key = (d, lower, transpose)
        bank = banks.get(key)
        if bank is None:
            bank = banks[key] = api.FactorBank(
                grid, d, n0=4, capacity=1, lower=lower,
                transpose=transpose, dtype=np.float32)
        return bank

    return get


@given(d=st.sampled_from([4, 8, 12]), lower=st.booleans(),
       transpose=st.booleans(), seed=st.integers(0, 2 ** 16))
@settings(max_examples=24, deadline=None)
def test_padded_bucket_solve_bit_identical(_pad_banks, d, lower,
                                           transpose, seed):
    """DESIGN.md Sec. 12 padding contract, property-tested: admitting
    an order-d factor into an order-n bucket with pad_to=n solves the
    leading d x k block BIT-IDENTICALLY to an unpadded width-1 order-d
    bank at the same n0, with an exact-zero tail — across orders,
    lower/upper, transpose, and random factors."""
    from repro import api
    n, k = 16, 3
    rng = np.random.default_rng(seed)
    T = np.tril(rng.standard_normal((d, d))) + d * np.eye(d)
    T = (T if lower else T.T).astype(np.float32)
    B = rng.standard_normal((d, k)).astype(np.float32)

    ref_bank = _pad_banks(d, lower, transpose)
    bucket = _pad_banks(n, lower, transpose)
    if ref_bank.size:
        ref_bank.replace(0, T)
    else:
        ref_bank.admit(T)
    if bucket.size:
        bucket.replace(0, T, pad_to=n)
    else:
        bucket.admit(T, pad_to=n)

    ref_solver = api.Solver.from_bank(ref_bank)
    Xr = np.asarray(ref_solver.solve(ref_solver.place_rhs(B[None])))[0]
    solver = api.Solver.from_bank(bucket)
    Bp = np.zeros((1, n, k), np.float32)
    Bp[0, :d] = B
    Xp = np.asarray(solver.solve(solver.place_rhs(Bp)))[0]
    np.testing.assert_array_equal(Xp[:d], Xr)
    np.testing.assert_array_equal(Xp[d:], np.zeros((n - d, k)))


def test_cost_model_monotonicity():
    """More processors never increases per-processor flop cost; latency
    of It-Inv never beats log^2 p."""
    from repro.core import cost_model as cm, tuning
    import math
    for p in [16, 64, 256, 1024]:
        plan = tuning.tune(1 << 14, 1 << 10, p)
        assert plan.cost.s >= math.log2(p) ** 2 * 0.5
    f_prev = None
    for p in [16, 64, 256]:
        plan = tuning.tune(1 << 14, 1 << 10, p)
        if f_prev is not None:
            assert plan.cost.f <= f_prev * 1.05
        f_prev = plan.cost.f


# ------------------- async FairQueue (weighted fair) -------------------

_arrival = st.tuples(st.sampled_from(["a", "b", "c"]),
                     st.integers(min_value=1, max_value=4))


def _fq(panel_k, weights=None, depth=10_000):
    from repro.core.serving import FairQueue, _Request
    fq = FairQueue(panel_k, depth, weights)

    def push(seq, tenant, width):
        fq.push(_Request(seq=seq, b=None, width=width, tenant=tenant,
                         key=0, gen=0, order=0, future=None))
    return fq, push


@given(arrivals=st.lists(_arrival, min_size=1, max_size=60),
       panel_k=st.sampled_from([4, 8, 16]))
@settings(max_examples=60, deadline=None)
def test_fairqueue_width_bound_fifo_no_starvation(arrivals, panel_k):
    """Async fair-packer invariants over arbitrary interleavings:
    every wave fits the panel, each tenant's requests come out in
    submit order, nothing starves (the queue always drains, in at most
    one wave per request), and a nonempty queue always yields a
    nonempty wave."""
    arrivals = [(t, min(w, panel_k)) for t, w in arrivals]
    fq, push = _fq(panel_k)
    for seq, (t, w) in enumerate(arrivals):
        push(seq, t, w)
    served, waves = [], 0
    while len(fq):
        wave = fq.pack()
        waves += 1
        assert wave, "a nonempty queue must always yield a wave"
        assert sum(r.width for r in wave) <= panel_k
        served.extend((r.tenant, r.seq) for r in wave)
    assert waves <= len(arrivals)                      # termination
    assert sorted(s for _, s in served) == list(range(len(arrivals)))
    for tenant in {t for t, _ in arrivals}:
        seqs = [s for t, s in served if t == tenant]
        assert seqs == sorted(seqs), "FIFO per tenant"


# ---------------- FactorStructure (DESIGN.md Sec. 14) ----------------


@given(m=st.sampled_from([2, 4, 8, 16]), density=st.sampled_from(
    [0.1, 0.4, 0.8]), seed=st.integers(0, 2 ** 16))
@settings(max_examples=60, deadline=None)
def test_structure_level_schedule_is_topological(m, density, seed):
    """The admission-time level schedule is a valid topological order
    of the block dependency DAG for ANY lower-triangular mask: block
    row i can only be scheduled after every j it reads (mask[i, j],
    j < i), and levels are dense 0..max with level 0 = rows that
    depend on nothing."""
    from repro.core.structure import FactorStructure, analyze
    rng = np.random.default_rng(seed)
    mask = np.tril(rng.random((m, m)) < density)
    np.fill_diagonal(mask, True)
    n0 = 4
    info = analyze(FactorStructure.block_sparse(mask), m * n0, n0)
    levels = info.levels
    assert len(levels) == m
    assert sorted(set(levels)) == list(range(max(levels) + 1))
    for i in range(m):
        deps = [j for j in range(i) if mask[i, j]]
        for j in deps:
            assert levels[j] < levels[i], (i, j, levels)
        if not deps:
            assert levels[i] == 0
        # spans cover every dependent of column i (conservatively)
        for j in deps:
            lo, hi = info.spans[j]
            assert lo <= i < hi, (i, j, info.spans[j])


@pytest.fixture(scope="module")
def _structure_banks():
    """A dense bank and a full-mask block_sparse bank sharing (n, n0),
    module-scoped so hypothesis examples reuse the two compiled
    programs and just replace the resident factor."""
    from repro import api
    grid = api.make_trsm_mesh(1, 1)
    n, n0 = 16, 4
    full = api.FactorStructure.block_sparse(
        np.tril(np.ones((n // n0, n // n0), dtype=bool)))
    dense = api.FactorBank(grid, n, n0=n0, capacity=1, dtype=np.float32)
    struct = api.FactorBank(grid, n, n0=n0, capacity=1, structure=full,
                            dtype=np.float32)
    return (api.Solver.from_bank(dense), dense,
            api.Solver.from_bank(struct), struct)


@given(seed=st.integers(0, 2 ** 16))
@settings(max_examples=20, deadline=None)
def test_full_mask_block_sparse_solves_bit_identical(_structure_banks,
                                                     seed):
    """A block_sparse structure whose mask keeps every lower block
    masks nothing and skips nothing — its solve must be BIT-identical
    to the dense bank's, across random factors and panels (DESIGN.md
    Sec. 14 dense-degeneracy contract)."""
    dsolver, dbank, ssolver, sbank = _structure_banks
    n, k = 16, 3
    rng = np.random.default_rng(seed)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    B = rng.standard_normal((1, n, k)).astype(np.float32)
    for bank in (dbank, sbank):
        bank.replace(0, L) if bank.size else bank.admit(L)
    Xd = np.asarray(dsolver.solve(dsolver.place_rhs(B.copy())))
    Xs = np.asarray(ssolver.solve(ssolver.place_rhs(B.copy())))
    np.testing.assert_array_equal(Xd, Xs)


@given(weights=st.tuples(st.integers(1, 5), st.integers(1, 5)),
       panel_k=st.sampled_from([4, 8, 16]),
       interleave=st.lists(st.sampled_from(["a", "b"]),
                           min_size=0, max_size=20))
@settings(max_examples=60, deadline=None)
def test_fairqueue_weights_honored_within_wave(weights, panel_k,
                                               interleave):
    """With both tenants fully backlogged on unit-width requests, ONE
    wave splits the panel proportionally to the tenant weights
    (within one column of the exact share) regardless of the arrival
    interleaving."""
    wa, wb = weights
    fq, push = _fq(panel_k, weights={"a": wa, "b": wb})
    # arbitrary interleaving prefix, then enough of both to backlog
    order = list(interleave) + ["a", "b"] * (2 * panel_k)
    counts = {"a": 0, "b": 0}
    for seq, t in enumerate(order):
        push(seq, t, 1)
        counts[t] += 1
    assert min(counts.values()) >= panel_k             # backlogged
    wave = fq.pack()
    assert len(wave) == panel_k                        # full panel
    got = sum(1 for r in wave if r.tenant == "a")
    exact = panel_k * wa / (wa + wb)
    assert abs(got - exact) <= 1, (got, exact)
