"""Roofline accounting validation.

The analytic flop model (repro.roofline.model) is validated against
XLA's cost_analysis on an UNROLLED lowering (no while loops, so the
while-body-once caveat doesn't apply).  Also checks the HLO collective
parser on a known program."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs import ModelConfig, ShapeConfig
from repro.models import lm
from repro.roofline import analysis, model


def _flops_of_unrolled(cfg, B, S):
    params = jax.eval_shape(lambda: lm.init(cfg, jax.random.key(0)))
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def fwd(p, t):
        return lm.forward(p, cfg, t, unroll=True, dtype=jnp.float32)[0]

    compiled = jax.jit(fwd).lower(params, toks).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca["flops"])


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-8b", "xlstm-1.3b"])
def test_analytic_flops_match_unrolled_compile(arch):
    cfg = configs.get_smoke(arch)
    B, S = 2, 128
    sh = ShapeConfig("t", S, B, "prefill")
    got = _flops_of_unrolled(cfg, B, S)
    want = model.forward_flops(cfg, sh, B * S)
    # matmul-dominated accounting: within 30% (elementwise ops and
    # softmax are uncounted; attention ctx is the causal average)
    assert 0.6 * want < got < 1.6 * want, (arch, got, want)


def test_analytic_flops_scale_with_depth_and_tokens():
    cfg = configs.get_smoke("granite-8b")
    sh1 = ShapeConfig("a", 128, 2, "prefill")
    sh2 = ShapeConfig("b", 256, 2, "prefill")
    f1 = model.forward_flops(cfg, sh1, 2 * 128)
    f2 = model.forward_flops(cfg, sh2, 2 * 256)
    assert f2 > 1.9 * f1   # superlinear (attention) but ~2x for small S
    cfg2 = dataclasses.replace(cfg, n_layers=cfg.n_layers * 2)
    assert model.forward_flops(cfg2, sh1, 256) > \
        1.5 * model.forward_flops(cfg, sh1, 256)


def test_cell_model_terms_positive_and_bottleneck():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shp in configs.SHAPES.values():
            ok, _ = configs.shape_applicable(cfg, shp)
            if not ok:
                continue
            cm = model.cell_model(cfg, shp, {"data": 16, "model": 16},
                                  microbatches=4)
            assert cm.flops > 0 and cm.hbm_bytes > 0
            assert cm.bottleneck in ("compute", "memory", "collective")
            assert cm.useful_ratio <= 1.05, (arch, shp.name,
                                             cm.useful_ratio)
            assert cm.roofline_fraction <= 1.0


def test_hlo_collective_parser():
    mesh = jax.make_mesh((1,), ("x",))
    # single-device: no collectives
    f = jax.jit(lambda a: a @ a)
    c = f.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    colls = analysis.parse_collectives(c.as_text())
    assert sum(v["bytes"] for v in colls.values()) == 0

    txt = """
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  %ag = bf16[64,512]{1,0} all-gather(%y), dimensions={0}
  %cp = f32[32]{0} collective-permute(%z), source_target_pairs={{0,1}}
"""
    colls = analysis.parse_collectives(txt)
    assert colls["all-reduce"]["bytes"] == 128 * 256 * 4
    assert colls["all-gather"]["bytes"] == 64 * 512 * 2
    assert colls["collective-permute"]["bytes"] == 32 * 4


def test_model_flops_for_kinds():
    cfg = configs.get("qwen3-1.7b")
    tr = analysis.model_flops_for(cfg, configs.SHAPES["train_4k"])
    pf = analysis.model_flops_for(cfg, configs.SHAPES["prefill_32k"])
    dc = analysis.model_flops_for(cfg, configs.SHAPES["decode_32k"])
    assert tr == 6.0 * cfg.flop_param_count * 4096 * 256
    assert pf == 2.0 * cfg.flop_param_count * 32768 * 32
    assert dc == 2.0 * cfg.flop_param_count * 128
    # flop params exclude the embedding gather but include the head
    # (equal for tied embeddings, strictly less for untied)
    assert cfg.flop_param_count == cfg.active_param_count  # qwen3: tied
    g = configs.get("granite-8b")
    assert g.flop_param_count < g.active_param_count       # untied
