"""Sharding-rule validation on the (abstract) production meshes: every
parameter/cache/batch spec must divide its dimension for all 10 full
architectures — the invariant that makes the 512-chip dry-run lower."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.compat import abstract_mesh
from repro.configs import ARCH_IDS, SHAPES
from repro.models import lm, whisper, sharding as sr

MESHES = {
    "single": abstract_mesh((16, 16), ("data", "model")),
    "multi": abstract_mesh((2, 16, 16), ("pod", "data", "model")),
}


def _axis_prod(mesh, dims):
    if dims is None:
        return 1
    if isinstance(dims, tuple):
        return int(np.prod([mesh.shape[d] for d in dims]))
    return mesh.shape[dims]


def _check_divisible(mesh, tree, shapes):
    flat_specs = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_shapes = jax.tree_util.tree_flatten_with_path(shapes)
    for (pth, spec), (_, leaf) in zip(flat_specs[0], flat_shapes[0]):
        for size, dim in zip(leaf.shape, spec):
            ax = _axis_prod(mesh, dim)
            assert size % ax == 0, (pth, leaf.shape, spec)


@pytest.mark.parametrize("mesh_name", ["single", "multi"])
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible(arch, mesh_name):
    cfg = configs.get(arch)
    mesh = MESHES[mesh_name]
    init = whisper.init if cfg.enc_dec else lm.init
    params = jax.eval_shape(lambda: init(cfg, jax.random.key(0)))
    specs = sr.param_specs(cfg, params, mesh)
    _check_divisible(mesh, specs, params)
    # fsdp_all mode must also stay divisible
    specs2 = sr.param_specs(cfg, params, mesh, mode="fsdp_all")
    _check_divisible(mesh, specs2, params)


@pytest.mark.parametrize("arch", ["llama3-405b", "grok-1-314b",
                                  "recurrentgemma-2b", "xlstm-1.3b",
                                  "whisper-tiny"])
def test_cache_specs_divisible(arch):
    cfg = configs.get(arch)
    mesh = MESHES["single"]
    sh = SHAPES["decode_32k"]
    init_cache = whisper.init_cache if cfg.enc_dec else lm.init_cache
    cache = jax.eval_shape(
        lambda: init_cache(cfg, sh.global_batch, sh.seq_len))
    specs = sr.cache_specs(cfg, cache, mesh)
    _check_divisible(mesh, specs, cache)


def test_ep_fallback_for_few_experts():
    """grok (8 experts < 16-way model axis) must shard expert FFN width
    over TP instead of replicating 1.2 TB of experts per device."""
    cfg = configs.get("grok-1-314b")
    mesh = MESHES["single"]
    params = jax.eval_shape(lambda: lm.init(cfg, jax.random.key(0)))
    specs = sr.param_specs(cfg, params, mesh)
    gate_spec = specs["units"]["b0"]["moe"]["gate"]
    # (units, E, D, F): model axis must appear somewhere
    flat = [d for d in gate_spec if d is not None]
    assert any("model" in (d if isinstance(d, tuple) else (d,))
               for d in flat), gate_spec
    # arctic (128 experts) keeps true EP on the expert dim
    cfg2 = configs.get("arctic-480b")
    params2 = jax.eval_shape(lambda: lm.init(cfg2, jax.random.key(0)))
    specs2 = sr.param_specs(cfg2, params2, mesh)
    gate2 = specs2["units"]["b0"]["moe"]["gate"]
    assert gate2[1] == "model", gate2     # (units, E, D, F): E on model


def test_batch_specs_modes():
    mesh = MESHES["single"]
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    b2d = sr.batch_specs(batch, mesh)
    assert b2d["tokens"][0] in ("data", ("data",))
    assert b2d["tokens"][1] is None
    bsp = sr.batch_specs(batch, mesh, mode="fsdp_all")
    assert bsp["tokens"][1] == "model"    # sequence parallelism
    # multi-pod: batch over (pod, data)
    mesh3 = MESHES["multi"]
    b3 = sr.batch_specs(batch, mesh3)
    assert b3["tokens"][0] == ("pod", "data")