"""Device-resident solve pipeline: on-device cyclic permutations vs the
NumPy reference, the compiled-solver cache, and TrsmSession's
zero-transfer / zero-retrace steady state (single-device grid; the
multi-device versions run in repro.core.selfcheck session)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core
from repro.core import grid as gridlib, session
from repro.core.grid import (cyclic_matrix_device, cyclic_rows_device,
                             from_cyclic_matrix, from_cyclic_rows,
                             to_cyclic_matrix, to_cyclic_rows)


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return gridlib.make_trsm_mesh(1, 1)


def _mats(n=64, k=8, seed=0):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, k))
    return L, B


# ---------------- device permutations == NumPy reference ----------------

@pytest.mark.parametrize("n,p", [(16, 1), (16, 2), (64, 4), (60, 3)])
def test_cyclic_rows_device_roundtrip(n, p):
    a = np.random.default_rng(n * p).standard_normal((n, 5))
    dev = np.asarray(cyclic_rows_device(jnp.asarray(a), p))
    np.testing.assert_array_equal(dev, to_cyclic_rows(a, p))
    back = np.asarray(cyclic_rows_device(jnp.asarray(dev), p,
                                         inverse=True))
    np.testing.assert_array_equal(back, a)
    np.testing.assert_array_equal(back, from_cyclic_rows(dev, p))


@pytest.mark.parametrize("n,p", [(16, 2), (64, 4)])
def test_cyclic_rows_device_reversal(n, p):
    """reverse=True folds the upper/transpose reversal identity into the
    same single gather: forward == to_cyclic(a[::-1])."""
    a = np.random.default_rng(1).standard_normal((n, 3))
    fwd = np.asarray(cyclic_rows_device(jnp.asarray(a), p, reverse=True))
    np.testing.assert_array_equal(fwd, to_cyclic_rows(a[::-1], p))
    back = np.asarray(cyclic_rows_device(jnp.asarray(fwd), p,
                                         inverse=True, reverse=True))
    np.testing.assert_array_equal(back, a)


@pytest.mark.parametrize("pr,pc", [(1, 1), (2, 4), (4, 2)])
def test_cyclic_matrix_device_matches_numpy(pr, pc):
    A = np.random.default_rng(2).standard_normal((32, 32))
    dev = np.asarray(cyclic_matrix_device(jnp.asarray(A), pr, pc))
    np.testing.assert_array_equal(dev, to_cyclic_matrix(A, pr, pc))
    back = np.asarray(cyclic_matrix_device(jnp.asarray(dev), pr, pc,
                                           inverse=True))
    np.testing.assert_array_equal(back, from_cyclic_matrix(
        to_cyclic_matrix(A, pr, pc), pr, pc))
    np.testing.assert_array_equal(back, A)


def test_cyclic_matrix_device_reversal_transpose():
    """The operator reductions: JAJ (reversal) and A^T, as one gather."""
    A = np.random.default_rng(3).standard_normal((16, 16))
    pr, pc = 2, 4
    rev = np.asarray(cyclic_matrix_device(
        jnp.asarray(A), pr, pc, reverse_rows=True, reverse_cols=True))
    np.testing.assert_array_equal(rev, to_cyclic_matrix(A[::-1, ::-1],
                                                        pr, pc))
    tr = np.asarray(cyclic_matrix_device(jnp.asarray(A), pr, pc,
                                         transpose=True))
    np.testing.assert_array_equal(tr, to_cyclic_matrix(A.T, pr, pc))


# ------------------- solve correctness via the pipeline -------------------

@pytest.mark.parametrize("method", ["inv", "rec"])
@pytest.mark.parametrize("lower,transpose", [(True, False), (False, False),
                                             (True, True), (False, True)])
def test_trsm_variants_device_pipeline(grid, method, lower, transpose):
    L, B = _mats()
    A = L if lower else L.T
    op = A.T if transpose else A
    X = core.trsm(A, B, grid, method=method, n0=16, lower=lower,
                  transpose=transpose)
    np.testing.assert_allclose(op @ np.asarray(X), B, atol=1e-3)


# ------------------------------ the cache ------------------------------

def test_solver_cache_reuses_compiled_program(grid):
    L, B = _mats()
    session.default_cache().clear()
    session.TRACE_COUNTS.clear()
    X1 = core.trsm(L, B, grid, method="inv", n0=16)
    X2 = core.trsm(L, B, grid, method="inv", n0=16)
    np.testing.assert_allclose(np.asarray(X1), np.asarray(X2))
    st = session.default_cache().stats()
    assert st["misses"] == 1 and st["hits"] == 1, st
    # one cached program, traced exactly once across both calls
    (key,) = list(session.TRACE_COUNTS)
    assert session.TRACE_COUNTS[key] == 1
    # a different shape is a different program
    core.trsm(L, B[:, :4], grid, method="inv", n0=16)
    assert session.default_cache().stats()["misses"] == 2


def test_solver_cache_lru_eviction(grid):
    cache = session.CompiledSolverCache(maxsize=2)
    L, B = _mats(n=32, k=4)
    for k in (1, 2, 4):
        session.get_solver(grid, n=32, k=k, dtype=np.float64,
                           method="inv", n0=8, cache=cache)
    assert len(cache) == 2 and cache.evictions == 1


# The session invariants — zero steady-state host transfers, zero
# retraces — must hold for EVERY precision preset: the refinement loop
# is unrolled inside the one compiled program, so a refined solve is
# still a single executable with no host round-trips.
@pytest.mark.parametrize("precision,in_dt,rtol", [
    (None, np.float64, 1e-10),          # legacy uniform-dtype policy
    ("fp32", np.float32, 1e-5),
    ("bf16", np.float32, 5e-2),
    ("bf16_refine", np.float32, 1e-5),
    ("fp64_refine", np.float64, 1e-11),
])
def test_session_steady_state_no_transfers_no_retraces(grid, precision,
                                                       in_dt, rtol):
    L, _ = _mats(n=64, k=8)
    L = L.astype(in_dt)
    sess = core.TrsmSession(L, grid, method="inv", n0=16,
                            precision=precision)
    sess.warmup(8)
    key = sess.program_for(8).key
    traces_after_warmup = session.TRACE_COUNTS[key]
    assert traces_after_warmup == 1     # one trace per cached program
    rng = np.random.default_rng(7)
    Bs = [sess.place_rhs(rng.standard_normal((64, 8)).astype(in_dt))
          for _ in range(4)]
    refs = [np.asarray(b) for b in Bs]
    with jax.transfer_guard("disallow"):
        outs = [sess.solve(b) for b in Bs]      # donate=True: B consumed
    assert session.TRACE_COUNTS[key] == traces_after_warmup
    for b, x in zip(refs, outs):
        assert x.dtype == sess.dtype
        x64 = np.asarray(x, np.float64)
        rel = (np.linalg.norm(L.astype(np.float64) @ x64 - b)
               / np.linalg.norm(b))
        assert rel < rtol, (precision, rel)
    assert sess.solves_served == 5              # warmup + 4


def test_multifactor_cache_sharing_no_baked_constants(grid):
    """Two same-shape sessions with DIFFERENT factor values must share
    one compiled program — the factor is a runtime operand, never a
    constant folded into the executable — and the same must hold for
    same-width factor banks (the batched program is keyed on the bank
    width, not on the factors)."""
    from repro.core.bank import BatchedTrsmSession, FactorBank
    session.default_cache().clear()
    session.TRACE_COUNTS.clear()
    L1, B = _mats(seed=1)
    L2, _ = _mats(seed=2)

    s1 = core.TrsmSession(L1, grid, method="inv", n0=16)
    s2 = core.TrsmSession(L2, grid, method="inv", n0=16)
    X1 = s1.solve(s1.place_rhs(B))
    X2 = s2.solve(s2.place_rhs(B))
    assert s1.program_for(8).key == s2.program_for(8).key
    (key,) = list(session.TRACE_COUNTS)
    assert session.TRACE_COUNTS[key] == 1          # one trace, two sessions
    st = session.default_cache().stats()
    assert st["misses"] == 1 and st["hits"] >= 1, st
    # different factors -> different (correct) answers: nothing baked in
    np.testing.assert_allclose(L1 @ np.asarray(X1), B, atol=1e-8)
    np.testing.assert_allclose(L2 @ np.asarray(X2), B, atol=1e-8)
    assert not np.allclose(np.asarray(X1), np.asarray(X2))

    # the bank: same width + config -> one batched program, two banks
    session.TRACE_COUNTS.clear()
    Ls_a = np.stack([L1, L2])
    Ls_b = np.stack([L2, L1])
    banks = []
    for Ls in (Ls_a, Ls_b):
        bank = FactorBank(grid, 64, n0=16, dtype=np.float64)
        bank.admit_stack(Ls)
        banks.append(BatchedTrsmSession(bank))
    Bs = np.stack([B, B])
    Xa = banks[0].solve(banks[0].place_rhs(Bs))
    Xb = banks[1].solve(banks[1].place_rhs(Bs))
    bkey = banks[0].program_for(8).key
    assert bkey == banks[1].program_for(8).key and bkey != key
    assert session.TRACE_COUNTS[bkey] == 1         # one trace, two banks
    for Ls, X in ((Ls_a, Xa), (Ls_b, Xb)):
        for i in range(2):
            np.testing.assert_allclose(Ls[i] @ np.asarray(X[i]), B,
                                       atol=1e-8)
    assert not np.allclose(np.asarray(Xa), np.asarray(Xb))


def test_session_rejects_bad_rhs(grid):
    L, _ = _mats(n=32, k=4)
    sess = core.TrsmSession(L, grid, method="inv", n0=8)
    with pytest.raises(ValueError):
        sess.solve(jnp.zeros((16, 4)))
    with pytest.raises(ValueError):
        core.TrsmSession(np.zeros((8, 4)), grid)


# -------------------------- request batching --------------------------

def test_trsm_request_server_packs_and_answers():
    from repro.train import serve_step as ss
    n = 64
    rng = np.random.default_rng(5)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    server = ss.make_trsm_server(L, panel_k=4, n0=16)
    reqs = [rng.standard_normal((n, w)) for w in (1, 3, 2, 4, 1)]
    for r in reqs:
        server.submit(r)
    outs = server.drain()
    assert server.pending() == 0
    assert [o.shape[1] for o in outs] == [1, 3, 2, 4, 1]
    for r, x in zip(reqs, outs):
        np.testing.assert_allclose(L @ np.asarray(x), r, atol=1e-8)
    with pytest.raises(ValueError):
        server.submit(rng.standard_normal((n, 9)))   # wider than panel


def test_trsm_request_server_first_fit_no_head_of_line_underfill():
    """A wide head-of-line request must not strand narrow requests into
    underfilled panels: widths (3, 4, 1) at panel_k=4 pack as [3+1],
    [4] — two panels, not three — and drain still returns solutions in
    submit order."""
    from repro.train import serve_step as ss
    n = 64
    rng = np.random.default_rng(6)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    server = ss.make_trsm_server(L, panel_k=4, n0=16)
    reqs = [rng.standard_normal((n, w)) for w in (3, 4, 1)]
    for r in reqs:
        server.submit(r)
    outs = server.drain()
    assert server.panels_solved == 2, server.panels_solved
    assert [o.shape[1] for o in outs] == [3, 4, 1]   # submit order
    for r, x in zip(reqs, outs):
        np.testing.assert_allclose(L @ np.asarray(x), r, atol=1e-8)


# ----------------------- degenerate kernel blocks -----------------------

def test_block_inv_kernel_rejects_degenerate_blocks():
    from repro.kernels import ops
    with pytest.raises(ValueError, match="degenerate"):
        ops.block_inv_kernel(jnp.zeros((4, 0, 0)))
    with pytest.raises(ValueError, match="degenerate"):
        ops.block_inv_kernel(jnp.zeros((0, 4, 4)))
    with pytest.raises(ValueError, match="square"):
        ops.block_inv_kernel(jnp.zeros((2, 4, 8)))
    with pytest.raises(ValueError, match="stack"):
        ops.block_inv_kernel(jnp.zeros((4, 4)))
    # n0=1 is fine (pure-jnp path), and valid blocks still invert
    out = ops.block_inv_kernel(jnp.ones((3, 1, 1)))
    np.testing.assert_allclose(np.asarray(out), np.ones((3, 1, 1)))
