"""The block-structure layer (repro.core.structure, DESIGN.md Sec. 14):
admission-time analysis (block masks, level schedules, update spans),
the level-scheduled sweep's correctness against dense references, the
dense-path bit-identity contract across every precision preset, the
structured steady state's zero-retrace / zero-transfer invariants at
occupancies 1 and C, and the structure-priced cost model / a-priori
plans (no compilation).

Single-device grid; small n so the structured sweeps stay in the fast
tier-1 set (``-m structure`` selects just these).  The hypothesis
variants of the schedule properties live in tests/test_property.py
(which importorskips hypothesis); the seeded sweeps here exercise the
same invariants without the dependency.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import cost_model as cm, grid as gridlib, session, tuning
from repro.core.structure import (FactorStructure, analyze,
                                  apply_block_mask)

pytestmark = pytest.mark.structure


@pytest.fixture(autouse=True, scope="module")
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module")
def grid():
    return gridlib.make_trsm_mesh(1, 1)


def _banded_factor(n, bw, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    i = np.arange(n)
    keep = (i[:, None] - i[None, :] <= bw) & (i[:, None] >= i[None, :])
    return np.where(keep, L, 0.0).astype(dtype), rng


def _random_block_mask(m, rng):
    bm = np.tril(rng.random((m, m)) < 0.4)
    np.fill_diagonal(bm, True)
    return bm


# --------------------------- the descriptor ---------------------------

def test_structure_constructors_and_hashing():
    d = FactorStructure.dense()
    assert d.is_dense and hash(d) == hash(FactorStructure("dense"))
    b = FactorStructure.banded(8)
    assert b == FactorStructure.banded(8) and b != FactorStructure.banded(9)
    m = np.tril(np.ones((4, 4), bool))
    s = FactorStructure.block_sparse(m)
    assert s == FactorStructure.block_sparse(m.tolist())
    assert isinstance(hash(s), int)          # nested-tuple normalized


def test_structure_validation_errors():
    with pytest.raises(ValueError, match="kind"):
        FactorStructure("diagonal")
    with pytest.raises(ValueError, match="bandwidth"):
        FactorStructure.banded(0)
    with pytest.raises(ValueError, match="no bandwidth"):
        FactorStructure("dense", bandwidth=4)
    with pytest.raises(ValueError, match="square"):
        FactorStructure.block_sparse(np.ones((2, 3), bool))
    with pytest.raises(ValueError, match="lower=True"):
        FactorStructure.banded(4).validate_for(64, lower=False)
    with pytest.raises(ValueError, match="lower=True"):
        FactorStructure.banded(4).validate_for(64, transpose=True)
    with pytest.raises(ValueError, match="use dense"):
        FactorStructure.banded(64).validate_for(64)
    with pytest.raises(ValueError, match="granularity"):
        FactorStructure.block_sparse(
            np.tril(np.ones((3, 3), bool))).validate_for(64)
    # dense is unrestricted
    FactorStructure.dense().validate_for(64, lower=False, transpose=True)


def test_structure_parse():
    assert FactorStructure.parse("dense").is_dense
    assert FactorStructure.parse("banded:16").bandwidth == 16
    assert FactorStructure.parse("banded", n=512).bandwidth == 64
    bs = FactorStructure.parse("block-sparse")
    assert bs.kind == "block_sparse" and len(bs.mask) == 8
    with pytest.raises(ValueError, match="needs n"):
        FactorStructure.parse("banded")
    with pytest.raises(ValueError, match="unknown structure"):
        FactorStructure.parse("butterfly")


def test_banded_block_mask_exact():
    # block (i, j)'s nearest element pair sits (i-j)*n0 - (n0-1) apart:
    # the mask must keep exactly the blocks the element band touches
    st = FactorStructure.banded(8)
    bm = st.block_mask(64, 8)
    d = np.subtract.outer(np.arange(8), np.arange(8))
    expect = (d >= 0) & (d * 8 - 7 <= 8)
    assert np.array_equal(bm, expect)
    # element band fully inside the diagonal blocks: bidiagonal blocks
    assert st.nnz_blocks(64, 8) == 8 + 7


def test_block_sparse_or_coarsening_is_conservative():
    rng = np.random.default_rng(3)
    src = _random_block_mask(8, rng)
    st = FactorStructure.block_sparse(src)
    for n0 in (8, 16, 32):
        bm = st.block_mask(64, n0)
        # every source nonzero must land inside a kept serving block
        g = 64 // 8
        for i in range(8):
            for j in range(i + 1):
                if src[i, j]:
                    assert bm[i * g // n0, j * g // n0]


# ------------------------ schedule properties ------------------------

def test_level_schedule_is_topological_seeded_sweep():
    # hypothesis variant: tests/test_property.py
    rng = np.random.default_rng(7)
    for trial in range(50):
        m = int(rng.integers(2, 17))
        bm = _random_block_mask(m, rng)
        st = FactorStructure.block_sparse(bm)
        info = analyze(st, m * 8, 8)
        levels = np.asarray(info.levels)
        for i in range(m):
            for j in range(i):
                if bm[i, j]:
                    # a dependency must be scheduled strictly earlier
                    assert levels[j] < levels[i], (trial, i, j)
        # levels are dense: every level up to the max is populated
        assert set(levels) == set(range(int(levels.max()) + 1))


def test_update_spans_cover_dependents_seeded_sweep():
    rng = np.random.default_rng(11)
    for trial in range(50):
        m = int(rng.integers(2, 17))
        bm = _random_block_mask(m, rng)
        info = analyze(FactorStructure.block_sparse(bm), m * 8, 8)
        for j in range(m):
            dep = np.nonzero(bm[j + 1:, j])[0] + j + 1
            if dep.size == 0:
                assert info.spans[j] is None
            else:
                lo, hi = info.spans[j]
                assert j + 1 <= lo <= dep.min()
                assert dep.max() < hi <= m
        assert info.update_cols == sum(
            1 for j in range(m) if bm[j + 1:, j].any())
        assert info.nnz_offdiag == int(bm.sum()) - m


def test_apply_block_mask_where_semantics():
    # jnp.where, not multiply: NaN/Inf in masked-out blocks must not
    # leak, and -0.0 inside kept blocks must survive bit-exactly
    st = FactorStructure.block_sparse(np.eye(2, dtype=bool))
    L = np.ones((16, 16), np.float32)
    L[8:, :8] = np.nan                       # the masked-OUT block
    L[0, 0] = -0.0
    out = np.asarray(apply_block_mask(jnp.asarray(L), st, 8))
    assert not np.isnan(out).any()
    assert (out[8:, :8] == 0).all()
    assert np.signbit(out[0, 0])             # -0.0 preserved
    # dense returns the SAME object (byte-identical path)
    x = jnp.asarray(L)
    assert apply_block_mask(x, FactorStructure.dense(), 8) is x


# ----------------------- solve-path correctness -----------------------

def test_banded_solve_matches_masked_reference(grid):
    n, k, bw = 64, 8, 8
    Lb, rng = _banded_factor(n, bw)
    B = rng.standard_normal((n, k)).astype(np.float32)
    solver = api.Solver.from_factor(
        Lb, grid, structure=FactorStructure.banded(bw))
    X = np.asarray(solver.solve(solver.place_rhs(B[None])))[0]
    ref = np.linalg.solve(Lb.astype(np.float64), B.astype(np.float64))
    rel = np.linalg.norm(X - ref) / np.linalg.norm(ref)
    assert rel < 1e-4, rel


def test_structured_admission_masks_the_operator(grid):
    # admission enforces the promise: a DENSE factor admitted under a
    # banded structure is served as the BLOCK-masked operator (the
    # mask is conservative at block granularity — elements inside a
    # kept block survive even below the element band)
    n, k, bw = 64, 4, 8
    rng = np.random.default_rng(5)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    B = rng.standard_normal((n, k)).astype(np.float32)
    st = FactorStructure.banded(bw)
    solver = api.Solver.from_factor(L, grid, structure=st)
    n0 = solver.bank.n0
    bm = st.block_mask(n, n0)
    Lm = np.where(np.repeat(np.repeat(bm, n0, 0), n0, 1), L, 0.0)
    X = np.asarray(solver.solve(solver.place_rhs(B[None])))[0]
    ref = np.linalg.solve(Lm.astype(np.float64), B.astype(np.float64))
    assert np.linalg.norm(X - ref) / np.linalg.norm(ref) < 1e-4


def test_full_mask_block_sparse_equals_dense_bitexact(grid):
    n, k = 64, 8
    rng = np.random.default_rng(2)
    L = (np.tril(rng.standard_normal((n, n)))
         + n * np.eye(n)).astype(np.float32)
    B = rng.standard_normal((n, k)).astype(np.float32)
    dense = api.Solver.from_factor(L, grid)
    m = n // dense.bank.n0
    full = api.Solver.from_factor(
        L, grid, n0=dense.bank.n0,
        structure=FactorStructure.block_sparse(
            np.tril(np.ones((m, m), bool))))
    Xd = np.asarray(dense.solve(dense.place_rhs(B[None])))
    Xf = np.asarray(full.solve(full.place_rhs(B[None])))
    assert Xd.tobytes() == Xf.tobytes()


@pytest.mark.parametrize("preset", ["fp32", "bf16", "bf16_refine",
                                    "fp64_refine"])
def test_dense_structure_bit_identity_per_preset(grid, preset):
    """The regression contract: structure=dense must be byte-identical
    to the unstructured path — same X bytes, same compiled program
    (TRACE_COUNTS unchanged by the second build: dense normalizes to
    None, so the two specs are the SAME cache key)."""
    n, k = 64, 8
    dt = np.float64 if preset == "fp64_refine" else np.float32
    rng = np.random.default_rng(4)
    L = (np.tril(rng.standard_normal((n, n))) + n * np.eye(n)).astype(dt)
    B = rng.standard_normal((n, k)).astype(dt)
    plain = api.Solver.from_factor(L, grid, precision=preset)
    Xp = np.asarray(plain.solve(plain.place_rhs(B[None])))
    key = plain.spec_for(k)
    traces = session.TRACE_COUNTS[key]
    structured = api.Solver.from_factor(
        L, grid, precision=preset, structure=FactorStructure.dense())
    skey = structured.spec_for(k)
    assert skey == key and skey.structure is None
    Xs = np.asarray(structured.solve(structured.place_rhs(B[None])))
    assert Xp.tobytes() == Xs.tobytes()
    assert session.TRACE_COUNTS[key] == traces   # shared program, no retrace


# ---------------------- steady-state invariants ----------------------

@pytest.mark.parametrize("occupancy", [1, 3])
def test_structured_steady_state_zero_retrace_zero_transfer(
        grid, occupancy):
    n, k, bw, C = 64, 8, 8, 3
    st = FactorStructure.banded(bw)
    bank = api.FactorBank(grid, n, capacity=C, structure=st,
                          dtype=np.float32)
    solver = api.Solver.from_bank(bank).warmup(k)
    Ls = [_banded_factor(n, bw, seed=20 + i)[0]
          for i in range(occupancy)]
    for L in Ls:                 # first admit compiles the updater
        bank.admit(L)
    fresh = _banded_factor(n, bw, seed=40)[0]
    placed = bank.place_factor(fresh)
    Ls[0] = fresh
    rng = np.random.default_rng(9)
    Bs = [solver.place_rhs(
        rng.standard_normal((C, n, k)).astype(np.float32))
        for _ in range(2)]
    refs = [np.asarray(b) for b in Bs]       # solve() donates the RHS
    key = solver.spec_for(k)
    uspec = bank.update_spec()
    traces = (session.TRACE_COUNTS[key], session.TRACE_COUNTS[uspec])
    with jax.transfer_guard("disallow"):
        bank.replace(bank.live_slots()[0], placed)   # steady churn
        outs = [solver.solve(b) for b in Bs]
    jax.block_until_ready(outs)
    assert (session.TRACE_COUNTS[key],
            session.TRACE_COUNTS[uspec]) == traces
    for X, Bref in zip(outs, refs):
        X = np.asarray(X)
        for i, L in enumerate(Ls):
            rel = (np.linalg.norm(
                L.astype(np.float64) @ X[i] - Bref[i])
                / np.linalg.norm(Bref[i]))
            assert rel < 1e-4, (i, rel)


def test_structured_bank_rejects_cyclic_ingestion(grid):
    st = FactorStructure.banded(8)
    bank = api.FactorBank(grid, 64, structure=st, dtype=np.float32)
    Lb, _ = _banded_factor(64, 8)
    with pytest.raises(ValueError, match="cyclic ingestion"):
        bank.admit_cyclic(jnp.asarray(Lb))
    with pytest.raises(ValueError, match="natural ingestion only"):
        api.UpdateSpec(n=64, grid=grid, policy=bank.policy,
                       method="inv", n0=bank.n0, mode=None, lower=True,
                       transpose=False, block_inv=None, bank_width=1,
                       ingest="cyclic", structure=st)


# ------------------------- cost model / plans -------------------------

def test_structured_cost_prices_skipped_blocks():
    n, n0, k = 512, 64, 16
    st = FactorStructure.banded(n // 8)
    dense = cm.update_phase_cost(n, k, n0, 2, 1)
    strct = cm.update_phase_cost(n, k, n0, 2, 1, structure=st)
    info = analyze(st, n, n0)
    m = n // n0
    fill = info.nnz_offdiag / (m * (m - 1) / 2)
    assert fill < 1
    assert strct.f == pytest.approx(dense.f * fill)
    assert strct.w == pytest.approx(dense.w * fill)
    assert strct.s == pytest.approx(
        dense.s * info.update_cols / (m - 1))
    # solve phase is structure-independent (every diagonal block is on
    # its own block row's critical path)
    steady_d = cm.it_inv_trsm_steady_cost(n, k, n0, 2, 1)
    steady_s = cm.it_inv_trsm_steady_cost(n, k, n0, 2, 1, structure=st)
    solve = cm.solve_phase_cost(n, k, n0, 2, 1)
    assert steady_s.f - solve.f == pytest.approx(strct.f)
    # rec is now priced from the structure's whole-factor block fill:
    # its L-proportional words/flops shrink, its message count (the
    # structure-blind recursion depth) does not
    rec_d = cm.rec_trsm_cost(n, k, 4)
    rec_s = cm.rec_trsm_cost(n, k, 4, structure=st)
    assert rec_s.s == rec_d.s
    assert rec_s.f < rec_d.f
    assert rec_s.w <= rec_d.w
    # and a dense FactorStructure prices identically to None
    assert cm.rec_trsm_cost(n, k, 4, structure=FactorStructure.dense()) \
        == rec_d


def test_auto_resolves_structured_plan_without_compiling():
    st = FactorStructure.banded(512 // 8)
    spec = api.SolveSpec.auto(512, 16, p=4, structure=st, hoisted=True)
    assert spec.structure == st
    assert spec.n0 is not None and 512 % spec.n0 == 0
    assert not spec.is_concrete            # plan-only grid: no devices
    # dense-structure auto normalizes to the unstructured key
    d = api.SolveSpec.auto(512, 16, p=4,
                           structure=FactorStructure.dense(),
                           hoisted=True)
    assert d.structure is None
    assert d == api.SolveSpec.auto(512, 16, p=4, hoisted=True)


def test_structured_serving_n0_feasible_and_cached():
    g = api.plan_grid(2, 1)
    st = FactorStructure.banded(64)
    n0 = tuning.serving_n0(512, g, structure=st)
    assert 512 % n0 == 0 and n0 % (g.p1 * g.p2) == 0 and n0 <= 256
    assert tuning.serving_n0(512, g, structure=st) == n0   # lru stable
    # dense path: byte-identical to the historical policy
    assert tuning.serving_n0(512, g) == \
        tuning.serving_n0(512, g, structure=FactorStructure.dense())


def test_plan_fleet_threads_structure():
    g = api.plan_grid(1, 1)
    st = FactorStructure.banded(16)
    plan = api.plan_fleet({256: 2, 128: 2}, g, k=8, structure=st)
    for b in plan.buckets:
        if b.method == "inv":
            assert b.structure == st


# ------------- validity-gated Pallas kernels (DESIGN.md Sec. 14) -------------

def test_trmm_block_mask_skips_poisoned_tiles():
    """``ops.trmm(block_mask=...)`` equals the unmasked kernel on the
    masked operand, and NEVER reads skipped tiles — NaNs planted in
    masked-out strictly-lower blocks must not reach the output."""
    from repro.kernels import ops
    rng = np.random.default_rng(11)
    n, k, bt = 128, 64, 32
    st = FactorStructure.banded(bt)
    mask = st.block_mask(n, bt)              # diag + first subdiagonal
    elem = np.repeat(np.repeat(mask, bt, 0), bt, 1)
    L = np.tril(rng.standard_normal((n, n))).astype(np.float32)
    Lm = np.where(elem, L, 0.0).astype(np.float32)
    X = rng.standard_normal((n, k)).astype(np.float32)
    want = np.asarray(ops.trmm(jnp.asarray(Lm), jnp.asarray(X),
                               bt=bt, bn=32))
    got = np.asarray(ops.trmm(jnp.asarray(Lm), jnp.asarray(X), bt=bt,
                              bn=32,
                              block_mask=jnp.asarray(mask, jnp.int32)))
    np.testing.assert_array_equal(got, want)
    poison = np.where(np.tril(elem, -1) | ~np.tri(n, dtype=bool),
                      Lm, np.nan)            # NaN exactly where skipped
    poison = np.where(elem, Lm, poison)
    got_p = np.asarray(ops.trmm(jnp.asarray(np.tril(poison)),
                                jnp.asarray(X), bt=bt, bn=32,
                                block_mask=jnp.asarray(mask, jnp.int32)))
    np.testing.assert_array_equal(got_p, want)


def test_tri_inv_blocks_valid_skips_and_zeros():
    """``ops.tri_inv_blocks(valid=...)`` writes zeros for flagged-out
    stack entries without reading them (a zero diagonal there would
    otherwise divide) and inverts the rest as usual."""
    from repro.kernels import ops
    rng = np.random.default_rng(12)
    m, n0 = 4, 16
    Ls = np.stack([np.tril(rng.standard_normal((n0, n0)))
                   + n0 * np.eye(n0) for _ in range(m)]
                  ).astype(np.float32)
    Ls[2] = 0.0                              # poison the skipped entry
    valid = jnp.asarray([1, 1, 0, 1], jnp.int32)
    out = np.asarray(ops.tri_inv_blocks(jnp.asarray(Ls), valid=valid))
    np.testing.assert_array_equal(out[2], np.zeros((n0, n0)))
    base = np.asarray(ops.tri_inv_blocks(jnp.asarray(Ls[[0, 1, 3]])))
    np.testing.assert_allclose(out[[0, 1, 3]], base,
                               rtol=1e-6, atol=1e-6)
    assert np.isfinite(out).all()


def test_trsm_substitution_valid_skips_and_zeros():
    """Same contract for the substitution baseline: flagged-out stack
    entries skip the recurrence (their zero diagonal never divides)
    and come back as zero panels."""
    from repro.kernels import ops
    rng = np.random.default_rng(13)
    m, n0, k = 3, 16, 8
    Ls = np.stack([np.tril(rng.standard_normal((n0, n0)))
                   + n0 * np.eye(n0) for _ in range(m)]
                  ).astype(np.float32)
    Bs = rng.standard_normal((m, n0, k)).astype(np.float32)
    Ls[1] = 0.0                              # poison the skipped entry
    valid = jnp.asarray([1, 0, 1], jnp.int32)
    out = np.asarray(ops.trsm_substitution(jnp.asarray(Ls),
                                           jnp.asarray(Bs),
                                           valid=valid))
    np.testing.assert_array_equal(out[1], np.zeros((n0, k)))
    keep = np.asarray(ops.trsm_substitution(jnp.asarray(Ls[[0, 2]]),
                                            jnp.asarray(Bs[[0, 2]])))
    np.testing.assert_allclose(out[[0, 2]], keep, rtol=1e-6, atol=1e-6)
    assert np.isfinite(out).all()
