"""Single-device substrate tests: data determinism, optimizers
(including the KFAC-CA 4-TRSM preconditioner), checkpoint round-trip,
fault-tolerance logic."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, optim
from repro.data import synthetic
from repro.models import lm
from repro.optim import schedules
from repro.train import checkpoint as ckpt, ft


# ------------------------------ data ------------------------------

def test_data_deterministic_and_disjoint():
    cfg = configs.get_smoke("qwen3-1.7b")
    b1 = synthetic.host_batch(cfg, 16, 8, step=3, host=0, n_hosts=2)
    b2 = synthetic.host_batch(cfg, 16, 8, step=3, host=0, n_hosts=2)
    assert np.array_equal(b1["tokens"], b2["tokens"])        # deterministic
    b3 = synthetic.host_batch(cfg, 16, 8, step=3, host=1, n_hosts=2)
    assert not np.array_equal(b1["tokens"], b3["tokens"])    # disjoint
    b4 = synthetic.host_batch(cfg, 16, 8, step=4, host=0, n_hosts=2)
    assert not np.array_equal(b1["tokens"], b4["tokens"])    # per-step
    # elastic re-partition: 1-host global == concat of 2-host slices
    g1 = synthetic.host_batch(cfg, 16, 8, step=3, host=0, n_hosts=1)
    np.testing.assert_array_equal(
        np.asarray(g1["tokens"]),
        np.concatenate([b1["tokens"], b3["tokens"]], axis=0))
    # labels are next-token shifted
    full = synthetic.host_batch(cfg, 16, 4, step=0)
    np.testing.assert_array_equal(np.asarray(full["tokens"][:, 1:]),
                                  np.asarray(full["labels"][:, :-1]))


def test_prefetcher():
    cfg = configs.get_smoke("qwen3-1.7b")
    pf = synthetic.Prefetcher(cfg, 8, 4, start_step=0, depth=2)
    s0, b0 = next(pf)
    s1, b1 = next(pf)
    pf.close()
    assert (s0, s1) == (0, 1)
    ref = synthetic.host_batch(cfg, 8, 4, step=0)
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(ref["tokens"]))


# ---------------------------- optimizers ----------------------------

def _quad_problem(key, d=16):
    """min ||W X - Y||^2 with known optimum."""
    k1, k2, k3 = jax.random.split(key, 3)
    X = jax.random.normal(k1, (d, 64))
    Wtrue = jax.random.normal(k2, (d, d))
    Y = Wtrue @ X
    W0 = jax.random.normal(k3, (d, d))

    def loss(p):
        return jnp.mean((p["w"] @ X - Y) ** 2)

    return {"w": W0}, loss


@pytest.mark.parametrize("name,kw", [
    ("adamw", dict(lr=3e-2)),
    ("kfac_ca", dict(lr=3e-2, min_dim=4)),
])
def test_optimizer_decreases_loss(name, kw):
    params, loss = _quad_problem(jax.random.key(0))
    opt = optim.get(name, **kw)
    state = opt.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s: opt.update(jax.grad(loss)(p), s, p))
    for _ in range(60):
        params, state, metrics = step(params, state)
    l1 = float(loss(params))
    assert l1 < 0.2 * l0, (name, l0, l1)
    assert np.isfinite(metrics["grad_norm"])


def test_kfac_preconditioner_is_inverse_application():
    """P = A^{-1} G B^{-1} via the 4-TRSM path must match dense solves."""
    from repro.optim.kfac_ca import _precondition
    rng = np.random.default_rng(0)
    do, di = 16, 32
    G = jnp.asarray(rng.standard_normal((do, di)), jnp.float32)
    Ma = rng.standard_normal((do, do))
    Mb = rng.standard_normal((di, di))
    A = jnp.asarray(Ma @ Ma.T, jnp.float32)
    B = jnp.asarray(Mb @ Mb.T, jnp.float32)
    damping = 1e-3
    P = _precondition(G, A, B, damping, mode="two_sided")
    lamA = damping * np.trace(A) / do
    lamB = damping * np.trace(B) / di
    want = np.linalg.solve(np.asarray(A) + lamA * np.eye(do), np.asarray(G))
    want = np.linalg.solve((np.asarray(B) + lamB * np.eye(di)).T, want.T).T
    np.testing.assert_allclose(np.asarray(P), want, rtol=2e-3, atol=2e-3)
    # inverse mode: (A + lI)^{-1} G on the smaller side
    Pw = _precondition(G, A, B, damping, mode="inverse")
    want_w = np.linalg.solve(np.asarray(A) + lamA * np.eye(do),
                             np.asarray(G))
    np.testing.assert_allclose(np.asarray(Pw), want_w, rtol=2e-3, atol=2e-3)
    # whiten mode with the exact Gram orthogonalizes: singulars ~ equal
    Ag = G @ G.T
    Po = _precondition(G, Ag, B, 1e-6, mode="whiten")
    s = np.linalg.svd(np.asarray(Po), compute_uv=False)
    assert s.max() / s.min() < 1.2, s
    # and matches the eigh-based inverse root applied to G
    w, V = np.linalg.eigh(np.asarray(Ag) + 1e-6 * np.trace(Ag) / do
                          * np.eye(do))
    root = (V * (w ** -0.5)) @ V.T
    np.testing.assert_allclose(np.asarray(Po), root @ np.asarray(G),
                               rtol=5e-3, atol=5e-3)


def test_kfac_on_tiny_lm():
    cfg = configs.get_smoke("smollm-360m")
    params = lm.init(cfg, jax.random.key(0))
    opt = optim.get("kfac_ca", lr=1e-2, min_dim=8, max_dim=512)
    state = opt.init(params)
    batch = synthetic.host_batch(cfg, 16, 4, step=0)

    @jax.jit
    def step(p, s, b):
        loss, g = jax.value_and_grad(
            lambda q: lm.loss_fn(q, cfg, b, dtype=jnp.float32))(p)
        p2, s2, _ = opt.update(g, s, p)
        return p2, s2, loss

    losses = []
    for i in range(8):
        b = synthetic.host_batch(cfg, 16, 4, step=0)  # fixed batch
        params, state, loss = step(params, state, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(l) for l in losses)


def test_schedules():
    lr = schedules.warmup_cosine(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(110)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(60)) < float(lr(20))


# ---------------------------- checkpoint ----------------------------

def test_checkpoint_roundtrip_bitexact():
    cfg = configs.get_smoke("qwen3-1.7b")
    params = lm.init(cfg, jax.random.key(0))
    opt = optim.get("adamw")
    state = {"params": params, "opt": opt.init(params)}
    with tempfile.TemporaryDirectory() as d:
        assert ckpt.latest_step(d) is None
        ckpt.save(d, 3, state)
        ckpt.save(d, 9, state)
        assert ckpt.latest_step(d) == 9
        like = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), state)
        restored, step = ckpt.restore(d, 9, like)
        assert step == 9
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_completeness():
    state = {"x": jnp.arange(100)}
    with tempfile.TemporaryDirectory() as d:
        t = ckpt.save(d, 1, state, blocking=False)
        t.join()
        assert ckpt.latest_step(d) == 1
        # a partial checkpoint (no manifest) is never 'latest'
        os.makedirs(os.path.join(d, "step_00000005"))
        assert ckpt.latest_step(d) == 1


# ------------------------- fault tolerance -------------------------

def test_restart_loop_resumes_and_bounds():
    calls = {"n": 0}

    def restore_fn():
        return {"start": calls["n"]}

    def train_fn(state):
        calls["n"] += 1
        if calls["n"] < 3:
            raise ft.WorkerFailure("injected")
        return "done"

    out, restarts = ft.run_with_restarts(train_fn, restore_fn=restore_fn,
                                         max_restarts=5)
    assert out == "done" and restarts == 2

    calls["n"] = 0

    def always_fail(state):
        calls["n"] += 1
        raise ft.WorkerFailure("injected")

    with pytest.raises(ft.WorkerFailure):
        ft.run_with_restarts(always_fail, restore_fn=restore_fn,
                             max_restarts=2)
    assert calls["n"] == 3    # 1 try + 2 restarts


def test_straggler_detection():
    mon = ft.StepMonitor(n_hosts=4, straggler_factor=1.5)
    for _ in range(10):
        for h, t in enumerate([1.0, 1.05, 0.95, 2.5]):
            mon.record(h, t)
    assert mon.stragglers() == [3]
    mon2 = ft.StepMonitor(n_hosts=2)
    mon2.record(0, 1.0)
    assert mon2.stragglers() == []   # not enough data


def test_train_restart_bitexact():
    """Kill a training run mid-way, restart from checkpoint: the final
    params must equal an uninterrupted run (deterministic pipeline)."""
    cfg = configs.get_smoke("smollm-360m")
    opt = optim.get("adamw", lr=1e-3)

    def run(n_steps, params, state, start=0):
        @jax.jit
        def step(p, s, b):
            loss, g = jax.value_and_grad(
                lambda q: lm.loss_fn(q, cfg, b, dtype=jnp.float32))(p)
            p2, s2, _ = opt.update(g, s, p)
            return p2, s2
        for i in range(start, n_steps):
            b = synthetic.host_batch(cfg, 16, 4, step=i)
            params, state = step(params, state, b)
        return params, state

    p0 = lm.init(cfg, jax.random.key(0))
    s0 = opt.init(p0)
    ref, _ = run(6, p0, s0)

    with tempfile.TemporaryDirectory() as d:
        p, s = run(3, p0, s0)            # run 3 steps, checkpoint, 'crash'
        ckpt.save(d, 3, {"p": p, "s": s})
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            {"p": p, "s": s})
        restored, st = ckpt.restore(d, ckpt.latest_step(d), like)
        p2, _ = run(6, restored["p"], restored["s"], start=st)

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
