"""Multi-device training/serving stack: subprocess selfchecks (8 forced
host devices; the main pytest process keeps 1 device)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

pytestmark = pytest.mark.slow


def run_selfcheck(name: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.train.selfcheck", name],
        capture_output=True, text=True, env=env, timeout=900)
    assert out.returncode == 0, \
        f"selfcheck {name} failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


@pytest.mark.parametrize("check", ["train_step", "serve_step", "pipeline",
                                   "compress", "ckpt_reshard"])
def test_train_selfcheck(check):
    out = run_selfcheck(check)
    assert "FAIL" not in out
    assert "0 failures" in out
