"""Public TRSM API: lower/upper/transposed solves, SPD solves, and the
comm tracer's scope bookkeeping."""

import jax
import numpy as np
import pytest

from repro import core
from repro.core import blocked, comm, grid as gridlib


@pytest.fixture(scope="module")
def grid():
    return gridlib.make_trsm_mesh(1, 1)


def _mats(n=64, k=8, seed=0):
    rng = np.random.default_rng(seed)
    L = np.tril(rng.standard_normal((n, n))) + n * np.eye(n)
    B = rng.standard_normal((n, k))
    return L, B


def test_trsm_lower(grid):
    L, B = _mats()
    X = core.trsm(L, B, grid, method="inv", n0=16)
    np.testing.assert_allclose(L @ X, B, atol=1e-3)


def test_trsm_upper(grid):
    L, B = _mats()
    U = L.T
    X = core.trsm(U, B, grid, method="inv", n0=16, lower=False)
    np.testing.assert_allclose(U @ X, B, atol=1e-3)


def test_trsm_transposed(grid):
    L, B = _mats()
    X = core.trsm(L, B, grid, method="inv", n0=16, transpose=True)
    np.testing.assert_allclose(L.T @ X, B, atol=1e-3)


def test_trsm_upper_rec(grid):
    L, B = _mats()
    X = core.trsm(L.T, B, grid, method="rec", n0=16, lower=False)
    np.testing.assert_allclose(L.T @ X, B, atol=1e-3)


# --------------------------- comm tracer ---------------------------

def test_comm_scope_multiplier():
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P

    def body(a):
        with comm.scope(5):
            b = comm.all_gather(a, "x", axis=0, tiled=True)
        return b

    from repro import compat
    fn = jax.jit(compat.shard_map(body, mesh=mesh, in_specs=P(),
                                  out_specs=P("x")))
    with comm.trace() as t:
        jax.eval_shape(fn, jax.ShapeDtypeStruct((4, 4), np.float32))
    # p=1: zero cost, but the record must carry the 5x multiplier
    assert len(t.records) == 1
    assert t.records[0].mult == 5.0
    assert t.s == 0.0     # log2(1) = 0


def test_comm_nested_scopes():
    with comm.trace() as t:
        with comm.scope(3):
            with comm.scope(4):
                comm._rec("allgather", "x", 8, 100, s=3.0, w=100.0, f=0.0)
    assert t.records[0].mult == 12.0
    assert t.s == 36.0
    assert t.w == 1200.0


def test_traced_cost_by_op():
    with comm.trace() as t:
        comm._rec("allreduce", "y", 4, 10, s=4.0, w=20.0, f=10.0)
        comm._rec("allreduce", "y", 4, 10, s=4.0, w=20.0, f=10.0)
        comm._rec("permute", "x", 2, 5, s=1.0, w=5.0, f=0.0)
    ops = t.by_op()
    assert ops["allreduce"]["count"] == 2
    assert ops["allreduce"]["w"] == 40.0
    assert ops["permute"]["s"] == 1.0
